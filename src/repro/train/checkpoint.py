"""Sharded, manifest-driven checkpointing with atomic publish.

Layout:  <dir>/step_<N>/
           manifest.json        — tree structure, shapes, dtypes, step
           shard_p<proc>.npz    — this process's leaf arrays
           COMMIT               — written last; a checkpoint without COMMIT
                                  is incomplete and ignored on restore

Writes go to ``step_<N>.tmp`` and are renamed into place only after COMMIT —
a crash mid-save can never corrupt the latest restorable state.  An optional
async mode snapshots to host memory and writes on a background thread so the
train loop is blocked only for the device→host copy.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _keys(tree) -> list:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _leaf in paths]


def save(ckpt_dir: str, step: int, state: dict, process_index: int = 0,
         async_: bool = False) -> str:
    """state: arbitrary pytree of arrays (params/opt/metadata)."""
    leaves, _ = _flatten(state)
    keys = _keys(state)
    host_leaves = [np.asarray(x) for x in leaves]      # device→host snapshot

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_p{process_index}.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "keys": keys,
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "n_processes": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return os.path.join(ckpt_dir, f"step_{step:08d}")
    return _write()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "COMMIT")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: dict, step: int | None = None,
            shardings=None, process_index: int = 0) -> tuple:
    """Returns (step, state) with arrays placed per ``shardings`` (or host)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"shard_p{process_index}.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(manifest["keys"]))]
    _, treedef = _flatten(like)
    if shardings is not None:
        sh_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
    state = jax.tree.unflatten(treedef, leaves)
    return step, state
