"""Data pipeline: deterministic, step-indexed, per-host sharded.

Restart-safe by construction: ``batch_at(step)`` is a pure function of
(seed, step), so a restarted job resumes bit-exactly from the checkpointed
step with no pipeline state to save (stateless skip-ahead).  Each host
materializes only its slice of the global batch.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: str = "none"       # "embed" archs get float frame embeddings
    d_model: int = 0


class TokenSource:
    """Base: deterministic per-step token batches."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            [self.cfg.seed, step, self.host_id])

    def tokens_at(self, step: int) -> np.ndarray:
        raise NotImplementedError

    def batch_at(self, step: int) -> dict:
        toks = self.tokens_at(step)                 # (local_batch, seq+1)
        batch = {"labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.frontend == "embed":
            rng = self._rng(step)
            batch["inputs"] = rng.standard_normal(
                (self.local_batch, self.cfg.seq_len, self.cfg.d_model),
                dtype=np.float32)
        else:
            batch["inputs"] = toks[:, :-1].astype(np.int32)
        return batch


class SyntheticTokens(TokenSource):
    """Zipfian synthetic tokens (vocab-realistic frequency skew)."""

    def tokens_at(self, step: int) -> np.ndarray:
        rng = self._rng(step)
        u = rng.random((self.local_batch, self.cfg.seq_len + 1))
        # inverse-CDF Zipf over the vocab (alpha ~1): cheap and heavy-tailed
        v = self.cfg.vocab_size
        toks = np.minimum((np.exp(u * np.log(v)) - 1).astype(np.int64),
                          v - 1)
        return toks


class FileTokens(TokenSource):
    """Memory-mapped flat token file (uint16/uint32), random chunks by step."""

    def __init__(self, path: str, cfg: DataConfig, dtype=np.uint16,
                 host_id: int = 0, n_hosts: int = 1):
        super().__init__(cfg, host_id, n_hosts)
        self.data = np.memmap(path, dtype=dtype, mode="r")
        assert len(self.data) > cfg.seq_len + 1, "token file too small"

    def tokens_at(self, step: int) -> np.ndarray:
        rng = self._rng(step)
        n = len(self.data) - self.cfg.seq_len - 1
        starts = rng.integers(0, n, size=self.local_batch)
        return np.stack([np.asarray(
            self.data[s:s + self.cfg.seq_len + 1]) for s in starts])


def make_source(cfg: DataConfig, path: str | None = None, **kw) -> TokenSource:
    if path:
        return FileTokens(path, cfg, **kw)
    return SyntheticTokens(cfg, **kw)
