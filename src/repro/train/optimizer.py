"""AdamW with optionally int8-quantized moments (ZeRO-3-sharded).

Optimizer state inherits the parameter sharding (params are already sharded
over ``data`` × ``model`` — ZeRO-3), so state memory divides by the full mesh.
For trillion-parameter configs even that is not enough on 16 GB chips, so
moments can be stored in int8 with per-row (last-axis) absmax scales — the
blockwise-quantized-Adam trick, laid out so array shapes (and therefore
sharding specs) are preserved.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"       # "float32" | "int8"
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


# ------------------------------------------------------------- quantization
def _quant(x: jax.Array):
    """Symmetric int8 with per-row (last-axis) absmax scale."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


# ------------------------------------------------------------------ states
def init_opt_state(params, cfg: OptConfig):
    def zeros_like_moment(p):
        if cfg.moment_dtype == "int8":
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "scale": jnp.zeros((*p.shape[:-1], 1), jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "mu": jax.tree.map(zeros_like_moment, params),
        "nu": jax.tree.map(zeros_like_moment, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(p_axes, cfg: OptConfig):
    """Sharding axes for the optimizer state, mirroring param axes."""
    def moment_axes(ax):
        if cfg.moment_dtype == "int8":
            return {"q": tuple(ax),
                    "scale": tuple(ax[:-1]) + (None,)}
        return tuple(ax)

    is_ax = lambda x: isinstance(x, tuple)          # noqa: E731
    return {
        "mu": jax.tree.map(moment_axes, p_axes, is_leaf=is_ax),
        "nu": jax.tree.map(moment_axes, p_axes, is_leaf=is_ax),
        "step": (),
    }


# ---------------------------------------------------------------- schedule
def lr_schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


# ------------------------------------------------------------------ update
def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, state, cfg: OptConfig, grad_sqnorm=None):
    """Returns (new_params, new_state, metrics).

    ``grad_sqnorm``: optional precomputed ``sum(g**2)`` over the whole tree —
    the overlapped pod sync accumulates it per bucket while later buckets'
    collectives are in flight, so the optimizer boundary doesn't redo the
    full-tree reduction.
    """
    step = state["step"] + 1
    lr = lr_schedule(step, cfg)
    gnorm = (jnp.sqrt(grad_sqnorm) if grad_sqnorm is not None
             else global_norm(grads))
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        if cfg.moment_dtype == "int8":
            mu_f = _dequant(mu["q"], mu["scale"])
            nu_f = _dequant(nu["q"], nu["scale"])
        else:
            mu_f, nu_f = mu, nu
        mu_f = b1 * mu_f + (1 - b1) * g
        nu_f = b2 * nu_f + (1 - b2) * g * g
        upd_ = (mu_f / bc1) / (jnp.sqrt(nu_f / bc2) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32)
                 - lr * (upd_ + wd * p.astype(jnp.float32))).astype(p.dtype)
        if cfg.moment_dtype == "int8":
            q1, s1 = _quant(mu_f)
            q2, s2 = _quant(nu_f)
            return new_p, {"q": q1, "scale": s1}, {"q": q2, "scale": s2}
        return new_p, mu_f, nu_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
