"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog.

Designed for the 1000+-node regime:

  * every ``ckpt_every`` steps the full state publishes atomically
    (checkpoint.py); on ANY step failure the loop restores the latest
    complete checkpoint and replays — the data pipeline is step-indexed so
    replays are bit-exact;
  * a step-duration watchdog classifies slow steps (> ``straggler_factor`` ×
    rolling median) and emits PASTA SYNC events — the hook a cluster
    scheduler uses for checkpoint-and-rebalance;
  * ``inject_failure_at`` deterministically raises mid-run (used by the
    elasticity tests to prove restart works).
"""

from __future__ import annotations

import dataclasses
import statistics
import time

import numpy as np

import repro.core as pasta
from . import checkpoint as ckpt


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = ""
    max_restarts: int = 3
    straggler_factor: float = 3.0
    async_ckpt: bool = False
    inject_failure_at: int | None = None     # test hook


class TrainLoop:
    def __init__(self, loop_cfg: LoopConfig, train_step, source,
                 place_batch, handler=None):
        """``train_step(params, opt, batch) -> (params, opt, metrics)``;
        ``source.batch_at(step)``; ``place_batch(np_batch) -> device batch``.
        Without an explicit ``handler`` the loop emits through the innermost
        active :class:`~repro.core.Session` (resolved per emission).
        """
        self.cfg = loop_cfg
        self.train_step = train_step
        self.source = source
        self.place_batch = place_batch
        self._handler = handler
        self.durations: list = []
        self.stragglers = 0
        self.restarts = 0

    @property
    def handler(self):
        return (self._handler if self._handler is not None
                else pasta.current_handler())

    # ---------------------------------------------------------------- loop
    def run(self, params, opt_state, start_step: int = 0,
            metrics_cb=None) -> tuple:
        step = start_step
        failed_once = set()
        while step < self.cfg.total_steps:
            try:
                params, opt_state, step = self._run_span(
                    params, opt_state, step, failed_once, metrics_cb)
            except Exception as e:                          # noqa: BLE001
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                if not self.cfg.ckpt_dir:
                    raise
                last = ckpt.latest_step(self.cfg.ckpt_dir)
                if last is None:
                    raise RuntimeError("failure before first checkpoint") \
                        from e
                last, state = ckpt.restore(self.cfg.ckpt_dir,
                                           {"params": params,
                                            "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                step = last
                self.handler.sync(f"restart_from_{last}")
        return params, opt_state, step

    def _run_span(self, params, opt_state, step, failed_once, metrics_cb):
        while step < self.cfg.total_steps:
            if self.cfg.inject_failure_at is not None \
                    and step == self.cfg.inject_failure_at \
                    and step not in failed_once:
                failed_once.add(step)
                raise RuntimeError(f"injected node failure at step {step}")
            self.handler.step_start(step)
            t0 = time.perf_counter()
            batch = self.place_batch(self.source.batch_at(step))
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            loss = float(metrics["loss"])              # sync point
            dur = time.perf_counter() - t0
            self._watchdog(step, dur)
            self.handler.step_end(step, loss=loss, duration_s=dur)
            if metrics_cb:
                metrics_cb(step, {k: float(np.asarray(v))
                                  for k, v in metrics.items()})
            step += 1
            if self.cfg.ckpt_dir and step % self.cfg.ckpt_every == 0:
                ckpt.save(self.cfg.ckpt_dir, step,
                          {"params": params, "opt": opt_state},
                          async_=self.cfg.async_ckpt)
        return params, opt_state, step

    # ------------------------------------------------------------ watchdog
    def _watchdog(self, step: int, dur: float) -> None:
        self.durations.append(dur)
        hist = self.durations[-50:]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dur > self.cfg.straggler_factor * med:
                self.stragglers += 1
                self.handler.sync(f"straggler_step_{step}")
