"""Training substrate: optimizer, trainer, data, checkpoint, elasticity."""

from .optimizer import OptConfig, init_opt_state, adamw_update, lr_schedule
from .trainer import (make_train_step, make_prefill_step, make_decode_step,
                      train_shardings, serve_shardings, abstract_state,
                      tree_shardings, batch_shardings)
from .data import DataConfig, SyntheticTokens, FileTokens, make_source
from . import checkpoint
from .elastic import LoopConfig, TrainLoop
