"""Step builders + sharding plumbing shared by train.py and dryrun.py.

``make_train_step`` builds a pjit-able function:

    (params, opt_state, batch) -> (params, opt_state, metrics)

with microbatched gradient accumulation (``lax.scan`` over microbatches —
one psum per accumulation window, the standard compute/comm-overlap layout),
global-norm clipping and AdamW.  Sharding trees are produced from the model's
logical param axes via :mod:`repro.dist.sharding`.

Cross-pod gradient sync (``overlap_sync=``):

* ``None`` (default) — the SPMD partitioner folds the pod reduction into the
  backward pass (batch sharded over ``("pod", "data")``), no explicit sync.
* ``False`` — explicit *blocking* sync: one synchronous
  :func:`~repro.dist.collectives.make_pod_sync` all-reduce per leaf at step
  end, serializing the slowest link behind the backward pass (the baseline
  the paper's overlap principle argues against).
* ``True`` — explicit *overlapped* sync: gradients are bucketed by layer
  group and each bucket's pod sync is issued as soon as the previous
  bucket's wait retires (``psum_start``/``psum_wait`` pipeline, 1F1B-style
  double buffering).  While bucket *g* is in flight, bucket *g−1*'s
  gradient-norm contribution is computed, so the only fully exposed
  transfer is the last bucket's and the optimizer boundary reuses the
  accumulated norm.

With an explicit sync the batch is *replicated* across pods
(``include_pod=False`` batch shardings): each pod computes full-batch
gradients and the explicit pod-mean is numerically the identity, so tier-1
numerics match the single-pod step exactly (modulo int8 quantization when
``sync_compressed=True``) while the HLO carries the full production
cross-pod collective structure — which is precisely what the PASTA walker
measures.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.collectives import make_pod_sync, psum_start, psum_wait
from repro.dist.sharding import logical, set_mesh
from repro.models import (forward, cross_entropy, init_params, param_axes,
                          init_cache, cache_axes)
from repro.models.config import ModelConfig
from .optimizer import OptConfig, init_opt_state, opt_state_axes, adamw_update

BATCH_AXES = ("pod", "data")


# ---------------------------------------------------------------- shardings
def tree_shardings(mesh, axes_tree, shapes_tree):
    """NamedSharding tree from logical-axes tree + abstract shapes tree."""
    from repro.dist.sharding import get_rules
    set_mesh(mesh, get_rules())          # keep any custom rules in force

    def one(ax, shape_leaf):
        return NamedSharding(mesh, logical(*ax, dims=shape_leaf.shape))

    is_ax = lambda x: isinstance(x, tuple)          # noqa: E731
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_ax)


def _dp_axes(mesh, batch_size: int | None = None, include_pod: bool = True):
    names = BATCH_AXES if include_pod else BATCH_AXES[1:]
    axes = tuple(a for a in names if mesh.shape.get(a, 1) > 1)
    if batch_size is not None:
        while axes:
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if batch_size % n == 0:
                break
            axes = axes[:-1]          # drop innermost dp axis until it fits
    return axes


def batch_spec(mesh, batch_size: int | None = None,
               include_pod: bool = True):
    return NamedSharding(mesh, P(_dp_axes(mesh, batch_size, include_pod)))


def batch_shardings(mesh, batch_tree, include_pod: bool = True):
    """``include_pod=False`` replicates the batch across pods — required by
    the explicit ``overlap_sync`` paths, whose pod-mean sync supplies the
    cross-pod reduction instead of the partitioner."""
    def one(leaf):
        return NamedSharding(mesh, P(_dp_axes(mesh, leaf.shape[0],
                                              include_pod),
                                     *([None] * (leaf.ndim - 1))))
    return jax.tree.map(one, batch_tree)


def abstract_state(cfg: ModelConfig, opt_cfg: OptConfig | None = None):
    """eval_shape of params (and optimizer state) — no allocation, works for
    the 1T-param config."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if opt_cfg is None:
        return params, None
    opt = jax.eval_shape(lambda: init_opt_state(params, opt_cfg))
    return params, opt


# --------------------------------------------------------------- train step
def _gather_once(params, cfg: ModelConfig):
    """ZeRO-2-style hoist: re-constrain params with the FSDP ('data') axis
    dropped so the all-gather happens once per step, before the microbatch
    loop — its transpose (one reduce-scatter of the summed grads) lands
    after the loop.  Trades (params/model-shard) bytes of HBM for
    (microbatches-1)/microbatches of the FSDP collective traffic."""
    from repro.dist.sharding import get_mesh, logical
    mesh = get_mesh()
    if mesh is None:
        return params
    axes = param_axes(cfg)
    is_ax = lambda x: isinstance(x, tuple)          # noqa: E731

    def regather(ax, p):
        ax2 = tuple(None if a == "p_embed" else a for a in ax)
        sh = NamedSharding(mesh, logical(*ax2, dims=p.shape))
        return jax.lax.with_sharding_constraint(p, sh)

    return jax.tree.map(regather, axes, params, is_leaf=is_ax)


# ----------------------------------------------------- overlapped pod sync
def _bucket_pieces(leaves, n_buckets: int, layer_dim: int | None):
    """Partition gradient leaves into ``n_buckets`` layer-group buckets.

    Scan-stacked leaves (leading dim == ``layer_dim``) are sliced along the
    layer axis so bucket *g* carries layer group *g* of every stacked leaf —
    the sync for a layer group covers exactly that group's parameters.
    Unstacked leaves (embeddings, final norm, ...) go whole to the currently
    lightest bucket.  Returns a list over buckets of ``(leaf_idx, lo, hi)``
    pieces (``lo is None`` ⇒ the whole leaf).
    """
    buckets: list = [[] for _ in range(n_buckets)]
    weight = [0] * n_buckets
    for i, leaf in enumerate(leaves):
        if (layer_dim is not None and leaf.ndim >= 1
                and leaf.shape[0] == layer_dim and layer_dim >= n_buckets):
            per = leaf.size // max(leaf.shape[0], 1) * leaf.dtype.itemsize
            for g in range(n_buckets):
                lo = g * layer_dim // n_buckets
                hi = (g + 1) * layer_dim // n_buckets
                buckets[g].append((i, lo, hi))
                weight[g] += per * (hi - lo)
        else:
            g = min(range(n_buckets), key=weight.__getitem__)
            buckets[g].append((i, None, None))
            weight[g] += leaf.size * leaf.dtype.itemsize
    return [b for b in buckets if b]


def make_overlapped_pod_sync(mesh, *, axis: str = "pod",
                             compressed: bool = False, n_buckets: int = 4,
                             layer_dim: int | None = None, specs=None):
    """Bucketed, software-pipelined cross-pod gradient sync.

    Returns ``sync(grads) -> (synced_grads, grad_sqnorm)`` (or ``None`` when
    the mesh has no pod axis).  Float leaves are bucketed by layer group
    (:func:`_bucket_pieces`); inside one ``shard_map`` over the mesh the
    buckets run through a ``psum_start``/``psum_wait`` double-buffered
    pipeline: bucket *g*'s reduce half is issued, THEN bucket *g−1*'s wait
    retires and its squared-norm contribution is computed — compute that
    overlaps the in-flight collective.  Only the last bucket's wait is fully
    exposed, and the accumulated ``grad_sqnorm`` lets the optimizer skip its
    own full-tree norm reduction (``adamw_update(grad_sqnorm=...)``).

    The sync is a pod *mean* (cross-pod data parallelism averages); see the
    module docstring for why that makes the step numerically identical to
    the single-pod step when the batch is pod-replicated.
    """
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return None
    inv_n = 1.0 / mesh.shape[axis]

    def sync(grads):
        leaves, treedef = jax.tree.flatten(grads)
        fidx = [i for i, l in enumerate(leaves)
                if jnp.issubdtype(l.dtype, jnp.floating)]
        buckets = _bucket_pieces([leaves[i] for i in fidx],
                                 n_buckets, layer_dim)

        def inner(flt):
            # flt: tuple of float leaves (replicated local views).  One flat
            # payload per bucket -> pipelined start/wait over the pod axis.
            def flat_of(bucket):
                return jnp.concatenate(
                    [(flt[j] if lo is None else flt[j][lo:hi])
                     .astype(jnp.float32).reshape(-1)
                     for j, lo, hi in bucket])

            outs: list = [None] * len(buckets)
            sq = jnp.zeros((), jnp.float32)

            def retire(g, handle):
                done = psum_wait(handle, axis) * inv_n
                outs[g] = done
                return sq + jnp.sum(done * done)

            def pin(wait_h, start_h, sq):
                # Pin the pipeline into the dataflow: bucket g-1's wait
                # (all-gather) may not retire before bucket g's start
                # (reduce-scatter) has issued and the previous bucket's
                # norm compute has run.  XLA's latency-hiding scheduler
                # does this implicitly on TPU; the optimization_barrier
                # makes the 1F1B schedule explicit in the HLO, which is
                # also what the PASTA walker's overlap windows measure.
                tied = jax.lax.optimization_barrier(
                    (wait_h.payload, start_h.payload, sq))
                return (dataclasses.replace(wait_h, payload=tied[0]),
                        dataclasses.replace(start_h, payload=tied[1]))

            prev = None
            for g, bucket in enumerate(buckets):
                handle = psum_start(flat_of(bucket), axis,
                                    compressed=compressed)
                if prev is not None:
                    prev, handle = pin(prev, handle, sq)
                    sq = retire(g - 1, prev)     # overlaps bucket g's wire
                prev = handle
            sq = retire(len(buckets) - 1, prev)  # the only exposed wait
            return tuple(outs), sq

        n_f = len(fidx)
        flat_specs = (tuple([P()] * n_f),)
        out_specs = (tuple([P()] * len(buckets)), P())
        f = shard_map(inner, mesh=mesh, in_specs=flat_specs,
                      out_specs=out_specs, check_rep=False)
        flats, sqnorm = f(tuple(leaves[i] for i in fidx))

        # unflatten: split each bucket payload back into its pieces
        new_leaves = list(leaves)
        parts: dict = {}
        for bucket, flat in zip(buckets, flats):
            off = 0
            for j, lo, hi in bucket:
                leaf = leaves[fidx[j]]
                shape = (leaf.shape if lo is None
                         else (hi - lo,) + tuple(leaf.shape[1:]))
                n = 1
                for d in shape:
                    n *= d
                piece = flat[off:off + n].reshape(shape).astype(leaf.dtype)
                off += n
                parts.setdefault(j, []).append((lo, piece))
        for j, pieces in parts.items():
            if len(pieces) == 1 and pieces[0][0] is None:
                new_leaves[fidx[j]] = pieces[0][1]
            else:
                pieces.sort(key=lambda t: t[0])
                new_leaves[fidx[j]] = jnp.concatenate(
                    [p for _lo, p in pieces], axis=0)
        return treedef.unflatten(new_leaves), sqnorm

    return sync


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    microbatches: int = 1, overlap_sync: bool | None = None,
                    sync_compressed: bool = False, sync_buckets: int = 4):
    def loss_fn(params, inputs, labels):
        logits, _ = forward(params, inputs, cfg)
        loss, parts = cross_entropy(logits, labels)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def pod_sync(grads):
        """(synced grads, optional precomputed sqnorm) per overlap_sync."""
        from repro.dist.sharding import get_mesh
        mesh = get_mesh()
        if overlap_sync is None or mesh is None:
            return grads, None
        if overlap_sync:
            sync = make_overlapped_pod_sync(
                mesh, compressed=sync_compressed, n_buckets=sync_buckets,
                layer_dim=cfg.n_layers)
            return (grads, None) if sync is None else sync(grads)
        return make_pod_sync(mesh, compressed=sync_compressed,
                             mean=True)(grads), None

    def train_step(params, opt_state, batch):
        inputs, labels = batch["inputs"], batch["labels"]
        if cfg.gather_params_once and microbatches > 1:
            params = _gather_once(params, cfg)
        if microbatches == 1:
            (loss, _parts), grads = grad_fn(params, inputs, labels)
        else:
            m = microbatches
            b = inputs.shape[0]
            assert b % m == 0, (b, m)
            mb_in = inputs.reshape(m, b // m, *inputs.shape[1:])
            mb_lb = labels.reshape(m, b // m, *labels.shape[1:])

            def micro(carry, mb):
                acc, lsum = carry
                (l, _p), g = grad_fn(params, mb["i"], mb["l"])
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)),
                {"i": mb_in, "l": mb_lb})
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = lsum / m
        grads, grad_sqnorm = pod_sync(grads)
        new_params, new_opt, om = adamw_update(params, grads, opt_state,
                                               opt_cfg,
                                               grad_sqnorm=grad_sqnorm)
        metrics = {"loss": loss, **om,
                   "tokens": jnp.asarray(inputs.shape[0] * inputs.shape[1],
                                         jnp.float32)}
        return new_params, new_opt, metrics

    return train_step


def train_shardings(mesh, cfg: ModelConfig, opt_cfg: OptConfig):
    """(param_shardings, opt_shardings) matching abstract_state."""
    p_shapes, o_shapes = abstract_state(cfg, opt_cfg)
    p_ax = param_axes(cfg)
    p_sh = tree_shardings(mesh, p_ax, p_shapes)
    o_ax = opt_state_axes(p_ax, opt_cfg)
    o_sh = tree_shardings(mesh, o_ax, o_shapes)
    return p_sh, o_sh, p_shapes, o_shapes


# --------------------------------------------------------------- serve step
def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, inputs):
        logits, cache = forward(params, inputs, cfg, return_cache=True,
                                logits_mode="last")
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens):
        logits, new_cache = forward(params, tokens, cfg, cache=cache,
                                    logits_mode="last")
        return logits, new_cache
    return decode_step


def serve_shardings(mesh, cfg: ModelConfig, batch: int, max_seq: int):
    params_shapes, _ = abstract_state(cfg)
    p_sh = tree_shardings(mesh, param_axes(cfg), params_shapes)
    cache_shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_seq))
    c_sh = tree_shardings(mesh, cache_axes(cfg), cache_shapes)
    return p_sh, c_sh, params_shapes, cache_shapes
