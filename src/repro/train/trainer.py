"""Step builders + sharding plumbing shared by train.py and dryrun.py.

``make_train_step`` builds a pjit-able function:

    (params, opt_state, batch) -> (params, opt_state, metrics)

with microbatched gradient accumulation (``lax.scan`` over microbatches —
one psum per accumulation window, the standard compute/comm-overlap layout),
global-norm clipping and AdamW.  Sharding trees are produced from the model's
logical param axes via :mod:`repro.dist.sharding`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import logical, set_mesh
from repro.models import (forward, cross_entropy, init_params, param_axes,
                          init_cache, cache_axes)
from repro.models.config import ModelConfig
from .optimizer import OptConfig, init_opt_state, opt_state_axes, adamw_update

BATCH_AXES = ("pod", "data")


# ---------------------------------------------------------------- shardings
def tree_shardings(mesh, axes_tree, shapes_tree):
    """NamedSharding tree from logical-axes tree + abstract shapes tree."""
    from repro.dist.sharding import get_rules
    set_mesh(mesh, get_rules())          # keep any custom rules in force

    def one(ax, shape_leaf):
        return NamedSharding(mesh, logical(*ax, dims=shape_leaf.shape))

    is_ax = lambda x: isinstance(x, tuple)          # noqa: E731
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_ax)


def _dp_axes(mesh, batch_size: int | None = None):
    axes = tuple(a for a in BATCH_AXES if mesh.shape.get(a, 1) > 1)
    if batch_size is not None:
        while axes:
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if batch_size % n == 0:
                break
            axes = axes[:-1]          # drop innermost dp axis until it fits
    return axes


def batch_spec(mesh, batch_size: int | None = None):
    return NamedSharding(mesh, P(_dp_axes(mesh, batch_size)))


def batch_shardings(mesh, batch_tree):
    def one(leaf):
        return NamedSharding(mesh, P(_dp_axes(mesh, leaf.shape[0]),
                                     *([None] * (leaf.ndim - 1))))
    return jax.tree.map(one, batch_tree)


def abstract_state(cfg: ModelConfig, opt_cfg: OptConfig | None = None):
    """eval_shape of params (and optimizer state) — no allocation, works for
    the 1T-param config."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if opt_cfg is None:
        return params, None
    opt = jax.eval_shape(lambda: init_opt_state(params, opt_cfg))
    return params, opt


# --------------------------------------------------------------- train step
def _gather_once(params, cfg: ModelConfig):
    """ZeRO-2-style hoist: re-constrain params with the FSDP ('data') axis
    dropped so the all-gather happens once per step, before the microbatch
    loop — its transpose (one reduce-scatter of the summed grads) lands
    after the loop.  Trades (params/model-shard) bytes of HBM for
    (microbatches-1)/microbatches of the FSDP collective traffic."""
    from repro.dist.sharding import get_mesh, logical
    mesh = get_mesh()
    if mesh is None:
        return params
    axes = param_axes(cfg)
    is_ax = lambda x: isinstance(x, tuple)          # noqa: E731

    def regather(ax, p):
        ax2 = tuple(None if a == "p_embed" else a for a in ax)
        sh = NamedSharding(mesh, logical(*ax2, dims=p.shape))
        return jax.lax.with_sharding_constraint(p, sh)

    return jax.tree.map(regather, axes, params, is_leaf=is_ax)


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    microbatches: int = 1):
    def loss_fn(params, inputs, labels):
        logits, _ = forward(params, inputs, cfg)
        loss, parts = cross_entropy(logits, labels)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        inputs, labels = batch["inputs"], batch["labels"]
        if cfg.gather_params_once and microbatches > 1:
            params = _gather_once(params, cfg)
        if microbatches == 1:
            (loss, _parts), grads = grad_fn(params, inputs, labels)
        else:
            m = microbatches
            b = inputs.shape[0]
            assert b % m == 0, (b, m)
            mb_in = inputs.reshape(m, b // m, *inputs.shape[1:])
            mb_lb = labels.reshape(m, b // m, *labels.shape[1:])

            def micro(carry, mb):
                acc, lsum = carry
                (l, _p), g = grad_fn(params, mb["i"], mb["l"])
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)),
                {"i": mb_in, "l": mb_lb})
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = lsum / m
        new_params, new_opt, om = adamw_update(params, grads, opt_state,
                                               opt_cfg)
        metrics = {"loss": loss, **om,
                   "tokens": jnp.asarray(inputs.shape[0] * inputs.shape[1],
                                         jnp.float32)}
        return new_params, new_opt, metrics

    return train_step


def train_shardings(mesh, cfg: ModelConfig, opt_cfg: OptConfig):
    """(param_shardings, opt_shardings) matching abstract_state."""
    p_shapes, o_shapes = abstract_state(cfg, opt_cfg)
    p_ax = param_axes(cfg)
    p_sh = tree_shardings(mesh, p_ax, p_shapes)
    o_ax = opt_state_axes(p_ax, opt_cfg)
    o_sh = tree_shardings(mesh, o_ax, o_shapes)
    return p_sh, o_sh, p_shapes, o_shapes


# --------------------------------------------------------------- serve step
def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, inputs):
        logits, cache = forward(params, inputs, cfg, return_cache=True,
                                logits_mode="last")
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens):
        logits, new_cache = forward(params, tokens, cfg, cache=cache,
                                    logits_mode="last")
        return logits, new_cache
    return decode_step


def serve_shardings(mesh, cfg: ModelConfig, batch: int, max_seq: int):
    params_shapes, _ = abstract_state(cfg)
    p_sh = tree_shardings(mesh, param_axes(cfg), params_shapes)
    cache_shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_seq))
    c_sh = tree_shardings(mesh, cache_axes(cfg), cache_shapes)
    return p_sh, c_sh, params_shapes, cache_shapes
